"""Shared benchmark utilities: timing + a small CIM-evaluated classifier.

The classifier stands in for the paper's CIFAR-10/ResNet-20 pipeline (no
datasets in this offline container): an MLP trained in float on a synthetic
Gaussian-cluster task, then evaluated with every matmul routed through the
simulated PICO-RAM macro. Accuracy deltas across schemes / ADC bits / PVT
corners reproduce the paper's TRENDS (Figs. 1b, 10, 18, 19); absolute
CIFAR numbers are out of scope offline.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CIMConfig, MacroConfig, cim_matmul


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (results blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def row(name: str, us: float, derived) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line


# ---------------------------------------------------------------------------
# synthetic classification task evaluated on the simulated macro
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TaskData:
    x_train: jax.Array
    y_train: jax.Array
    x_test: jax.Array
    y_test: jax.Array


def make_task(n_classes=16, dim=64, n_train=4096, n_test=1024, seed=0):
    key = jax.random.PRNGKey(seed)
    centers = jax.random.normal(key, (n_classes, dim)) * 1.5

    def sample(k, n):
        ky, kx = jax.random.split(k)
        y = jax.random.randint(ky, (n,), 0, n_classes)
        x = centers[y] + jax.random.normal(kx, (n, dim))
        return jax.nn.relu(x), y  # non-negative activations (paper's case)

    xtr, ytr = sample(jax.random.fold_in(key, 1), n_train)
    xte, yte = sample(jax.random.fold_in(key, 2), n_test)
    return TaskData(xtr, ytr, xte, yte)


def train_mlp(task: TaskData, hidden=144, steps=300, seed=0):
    """Plain float training; CIM enters only at evaluation (PTQ deployment,
    the harder case than QAT — trends match the paper's)."""
    key = jax.random.PRNGKey(seed + 100)
    dim = task.x_train.shape[1]
    n_classes = int(task.y_train.max()) + 1
    params = {
        "w1": jax.random.normal(key, (dim, hidden)) / np.sqrt(dim),
        "w2": jax.random.normal(jax.random.fold_in(key, 1),
                                (hidden, n_classes)) / np.sqrt(hidden),
    }

    def logits_fn(p, x):
        h = jax.nn.relu(x @ p["w1"])
        return h @ p["w2"]

    def loss_fn(p):
        lg = logits_fn(p, task.x_train)
        return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]),
                                                task.y_train])

    @jax.jit
    def step(p, m):
        g = jax.grad(loss_fn)(p)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        return jax.tree.map(lambda pp, mm: pp - 0.05 * mm, p, m), m

    m = jax.tree.map(jnp.zeros_like, params)
    for _ in range(steps):
        params, m = step(params, m)
    return params


def eval_accuracy(params, task: TaskData, macro: MacroConfig | None,
                  key=None) -> float:
    """Test accuracy with matmuls on the simulated macro (None = float)."""
    if macro is None:
        h = jax.nn.relu(task.x_test @ params["w1"])
        lg = h @ params["w2"]
    else:
        cfg = CIMConfig(enabled=True, macro=macro)
        k1 = k2 = None
        if key is not None:
            k1, k2 = jax.random.split(key)
        h = jax.nn.relu(cim_matmul(task.x_test, params["w1"], cfg, key=k1))
        lg = cim_matmul(h, params["w2"], cfg, key=k2)
    return float(jnp.mean((jnp.argmax(lg, -1) == task.y_test)))
