"""Fig. 19: end-to-end task accuracy across voltages / temperatures / gains.

Paper: ≤1.3 % degradation at corners vs nominal. Same protocol with the
synthetic classifier + FULL-fidelity macro sim (noise + INL, PVT-scaled).
"""
import dataclasses
import time

import jax

from repro.core import PROTOTYPE
from repro.core.macro import OperatingPoint, SimLevel

from .common import eval_accuracy, make_task, row, train_mlp


def run():
    task = make_task()
    params = train_mlp(task)
    acc_float = eval_accuracy(params, task, None)
    key = jax.random.PRNGKey(0)
    out = []
    t0 = time.perf_counter()

    def acc_at(**kw):
        # deployed operating point: gain 3 (paper Fig. 19 reports CIFAR
        # accuracy at gain 3 across the PVT corners)
        kw.setdefault("gain", 3.0)
        op = OperatingPoint(vdd=kw.pop("vdd", 0.9),
                            temp_c=kw.pop("temp_c", 25.0))
        m = dataclasses.replace(PROTOTYPE, op=op, sim_level=SimLevel.FULL,
                                **kw)
        return eval_accuracy(params, task, m, key=key)

    nominal = acc_at()
    out.append(row("fig19_nominal", (time.perf_counter() - t0) * 1e6,
                   f"acc={nominal:.4f}|float={acc_float:.4f}"))
    for vdd in (0.65, 0.8, 1.0, 1.2):
        out.append(row(f"fig19_vdd{vdd:g}", (time.perf_counter() - t0) * 1e6,
                       f"acc={acc_at(vdd=vdd):.4f}"))
    for temp in (-40.0, 105.0):
        out.append(row(f"fig19_temp{temp:g}",
                       (time.perf_counter() - t0) * 1e6,
                       f"acc={acc_at(temp_c=temp):.4f}"))
    for gain in (1.0, 2.0):
        out.append(row(f"fig19_gain{gain:g}",
                       (time.perf_counter() - t0) * 1e6,
                       f"acc={acc_at(gain=gain):.4f}"))
    return out


if __name__ == "__main__":
    run()
