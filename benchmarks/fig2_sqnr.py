"""Fig. 2: simulated SQNR + Eq. 4 energy across hardware configurations.

(a) quantization levels fixed at 64, sweep N;
(b) N = 144 fixed, sweep quantization levels.
Paper anchors: (a) BP(9) +1.8 dB vs WBS(36), +3.5 dB vs BS(144);
(b) BP(1024) +7.8 dB vs WBS(256), +21.6 dB vs BS(32) at iso-energy.
"""
import dataclasses
import time

from repro.core import PROTOTYPE, Scheme
from repro.core.sqnr import simulate_sqnr

N_MC = 1 << 13


def run():
    out = []
    t0 = time.perf_counter()

    def emit(name, cfg):
        r = simulate_sqnr(cfg, k=144, n_samples=N_MC)
        us = (time.perf_counter() - t0) * 1e6
        from .common import row
        out.append(row(name, us, f"sqnr_db={r.sqnr_db:.2f}|"
                                 f"E={r.energy_per_mvm_j:.3e}J"))

    # (a) levels=64, sweep N per scheme
    for scheme, ns in ((Scheme.BP, (9, 18, 36, 72, 144)),
                       (Scheme.WBS, (36, 144)), (Scheme.BS, (144,))):
        for n in ns:
            emit(f"fig2a_{scheme.value}_N{n}",
                 dataclasses.replace(PROTOTYPE, scheme=scheme, n_rows=n,
                                     adc_levels=64))
    # (b) N=144, sweep levels per scheme
    for scheme, lvls in ((Scheme.BP, (256, 362, 1024)),
                         (Scheme.WBS, (64, 256)), (Scheme.BS, (32, 64))):
        for lv in lvls:
            emit(f"fig2b_{scheme.value}_L{lv}",
                 dataclasses.replace(PROTOTYPE, scheme=scheme,
                                     adc_levels=lv))
    return out


if __name__ == "__main__":
    run()
