"""Fig. 1(b): energy efficiency × task accuracy of BP / WBS / BS.

Paper claim: BP ≈ 1.6× (WBS) and 6.4× (BS) better energy at iso-accuracy.
We report Eq. 4 energy-per-MVM and classifier accuracy per scheme at the
prototype operating point.
"""
import dataclasses
import time

from repro.core import PROTOTYPE, Scheme
from repro.core.energy import mvm_energy

from .common import eval_accuracy, make_task, row, train_mlp


def run():
    task = make_task()
    params = train_mlp(task)
    t0 = time.perf_counter()
    out = []
    acc_float = eval_accuracy(params, task, None)
    for scheme in (Scheme.BP, Scheme.WBS, Scheme.BS):
        macro = dataclasses.replace(PROTOTYPE, scheme=scheme)
        acc = eval_accuracy(params, task, macro)
        e = mvm_energy(macro, 144, dual_threshold=False)
        us = (time.perf_counter() - t0) * 1e6
        out.append(row(f"fig1b_{scheme.value}", us,
                       f"acc={acc:.4f}|float={acc_float:.4f}|"
                       f"E_mvm={e.e_mvm_j:.3e}J|TOPSW={e.tops_per_w:.1f}"))
    return out


if __name__ == "__main__":
    run()
